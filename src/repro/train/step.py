"""Jitted train / DMD steps.

train_step(state, batch, step):
  * microbatch gradient accumulation via lax.scan (per-arch grad_accum,
    resolved against the mesh so each microbatch keeps >= 1 row per batch
    shard),
  * fp32 gradient accumulators,
  * fused DMD snapshot recording, driven by the STEP INDEX: the per-group
    slot vector is computed in-trace (schedule.slots_for_step) and each
    schedule group gets its own lax.cond, so a group in warmup/phase/
    cooldown costs nothing while another group records (DESIGN.md §4). With
    dmd.streaming_gram the O(m*n) Gram row update rides in the same
    per-group cond, against params that are already resident from the
    optimizer update. The row pass is kernel-routed per leaf by the
    accelerator's LeafPlan table (DESIGN.md §3): Pallas for flat leaves,
    shard_map'd Pallas for stacked/sharded ones.
  * optional int8-compressed cross-pod gradient sync (distributed/gradsync).

dmd_step(state, relax, groups=None): the paper's jump, masked to the
schedule group(s) whose window closed (`groups` is a STATIC tuple — the
Trainer jits it as a static argname, so a staggered schedule compiles one
small program per jumping group instead of one whole-tree spike). With the
streaming Gram carried in TrainState it is pure O(m^3) coefficient algebra
+ one combine pass per jumped leaf; without it (the
cfg.streaming_gram=False A/B baseline) it recomputes the full O(m^2*n)
Gram. Both steps share the same accelerator instance (hence the same plan
table) — pass `acc=` to avoid rebuilding it.

Arena-native residency (dmd.arena_native, DESIGN.md §7): ``Trainer.fit``
converts the TrainState at entry via ``state_resident`` — packed leaves'
params and elementwise optimizer moments move INTO their bucket's
contiguous flat buffer (the ``{"__arena__": ..., "leaf": ...}`` wrapper,
core/arena.py) — and back via ``state_unresident`` before returning. The
step fns here are layout-driven: when the params are resident, the
model's forward sees zero-copy per-leaf VIEWS (static slice + reshape of
the flat buffer, expanded in-trace by ``arena.tree_leafwise``), the
optimizer update runs directly on the flat buffers (grads of loss∘views
transpose to pad-extended slices — pad lanes stay zero), and `record`
degenerates to one dynamic_update_slice per bucket. Residency only
engages for optimizers whose moment updates are elementwise
(``RESIDENT_OPTIMIZERS``): adafactor factors trailing dims and adam8bit
quantizes fixed 256-blocks, both of which read shape structure a flat
buffer destroys.

Donation contract (audited: tests/test_donation.py inspects the compiled
HLO's input_output_alias table): under the Trainer's
``jax.jit(..., donate_argnums=(0,))`` every snapshot buffer and Gram leaf
— per-leaf AND packed-arena — aliases input to output with ZERO
buffer-sized copies, in the fused train step and in BOTH dmd_step
variants. The gated (controller) step additionally aliases the whole
TrainState: the rollback branch passes the donated pre-jump params and
moments straight through. Callers that re-use a state after the call must
clone it or rethread the returned state (see the controller bench's
gate-overhead fix in benchmarks/paper_benches.py).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import arena as arena_mod
from repro.core import leafplan, schedule as sched_mod
from repro.core import snapshots as snap
from repro.core.accelerator import DMDAccelerator, _none_like, jump_tree
from repro.distributed.sharding import constrain
from repro.optim import apply_updates, make_optimizer
from repro.train.state import TrainState

PyTree = Any


def resolve_grad_accum(acfg, mesh, global_batch: int) -> int:
    """Largest accum factor <= config that keeps >=1 row per batch shard."""
    ga = max(acfg.parallel.grad_accum, 1)
    shards = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        shards = sizes.get("data", 1) * sizes.get("pod", 1)
    while ga > 1 and (global_batch // ga) % shards != 0:
        ga //= 2
    return max(min(ga, global_batch // shards), 1)


# Optimizers whose update is elementwise over each moment entry — the only
# ones whose moments can live in a flat arena buffer without changing the
# math. adafactor (factored trailing dims) and adam8bit (256-block absmax
# quantization) both read shape structure that flattening destroys.
RESIDENT_OPTIMIZERS = ("sgd", "momentum", "adam", "adamw")


def resident_enabled(acc: DMDAccelerator, acfg) -> bool:
    """Arena-native parameter residency gate (DESIGN.md §7): arenas on,
    cfg.dmd.arena_native on, and an elementwise-moment optimizer."""
    return (acc.arena_on
            and bool(getattr(acc.cfg, "arena_native", True))
            and acfg.optimizer.name in RESIDENT_OPTIMIZERS)


def state_resident(acc: DMDAccelerator, acfg, state):
    """Leafwise TrainState -> the arena-resident layout (params and
    params-shaped optimizer-moment fields packed into the bucket buffers).
    No-op when residency is gated off, nothing is packed, or the state is
    already resident. Off the hot path — Trainer.fit entry only."""
    if state is None or not resident_enabled(acc, acfg) \
            or arena_mod.is_arena_state(state.params):
        return state
    table = acc.arena_for(state.params)
    if not table:
        return state
    pdef = jax.tree_util.tree_structure(state.params)

    def to_res(field):
        # params-shaped moment trees pack; anything else (scalar counters,
        # empty states) passes through untouched
        if jax.tree_util.tree_structure(field) == pdef:
            return arena_mod.tree_resident(table, field)
        return field

    opt_state = state.opt_state
    if jax.tree_util.tree_structure(opt_state) == pdef:
        opt_state = arena_mod.tree_resident(table, opt_state)   # momentum
    elif isinstance(opt_state, tuple) and opt_state:            # NamedTuple
        opt_state = type(opt_state)(*(to_res(f) for f in opt_state))
    return state._replace(
        params=arena_mod.tree_resident(table, state.params),
        opt_state=opt_state)


def state_unresident(acc: DMDAccelerator, state):
    """Inverse of state_resident: expand resident params / moments back to
    the per-leaf layout. DMD buffers and Grams keep their packed arena
    layout (they are packed whenever arenas are on, residency or not);
    use acc.state_leafwise for the full checkpoint expansion."""
    if state is None or not arena_mod.is_arena_state(state.params):
        return state
    table = acc.arena_for(state.params)

    def unwrap(x):
        return (arena_mod.tree_leafwise(table, x)
                if arena_mod.is_arena_state(x) else x)

    return state._replace(
        params=arena_mod.tree_leafwise(table, state.params),
        opt_state=jax.tree_util.tree_map(
            unwrap, state.opt_state, is_leaf=arena_mod.is_arena_state))


def _accelerator_for(model, acfg, mesh, acc: Optional[DMDAccelerator]
                     ) -> DMDAccelerator:
    """Shared accelerator (and hence LeafPlan table) for the step builders:
    use the caller's, or build one wired to the model's structural stack-dim
    annotation."""
    if acc is not None:
        return acc
    sd = None
    if model is not None and hasattr(model, "param_stack_dims"):
        sd = model.param_stack_dims()
    return DMDAccelerator(acfg.dmd, mesh=mesh, stack_dims=sd)


def make_train_step(model, acfg, *, mesh=None, global_batch=None,
                    loss_fn: Callable = None, donate: bool = True,
                    acc: Optional[DMDAccelerator] = None):
    """Returns train_step(state, batch, step) -> (state, metrics).

    `step` is the (traced) optimizer-step index — the per-group DMD slot
    vector is derived from it in-trace, replacing the old single `dmd_slot`
    scalar (which could only express one global window)."""
    opt = make_optimizer(acfg.optimizer)
    gb = global_batch or acfg.train.global_batch
    ga = resolve_grad_accum(acfg, mesh, gb)
    dmd_on = acfg.dmd.enabled
    acc = _accelerator_for(model, acfg, mesh, acc)
    streaming_on = acc.streaming
    _loss = loss_fn or (lambda p, b: model.loss(p, b)[0])

    def train_step(state: TrainState, batch: PyTree, step) -> tuple:
        params = state.params
        # Arena-RESIDENT params (dmd.arena_native): the model's forward
        # sees zero-copy per-leaf views of the flat bucket buffers —
        # static slice + reshape, expanded in-trace. Grads of loss∘views
        # transpose to pad-extended slices of the flat cotangent, so the
        # optimizer update below runs directly on the flat buffers.
        resident = arena_mod.is_arena_state(params)
        table = acc.arena_for(params) if resident else None

        def one_loss(p, mb):
            if resident:
                p = arena_mod.tree_leafwise(table, p)
            return _loss(p, mb)

        if ga > 1:
            def reshape_mb(x):
                return x.reshape((ga, x.shape[0] // ga) + x.shape[1:])
            mbs = jax.tree_util.tree_map(reshape_mb, batch)
            mbs = jax.tree_util.tree_map(
                lambda x: constrain(x, None, "batch"), mbs)

            def mb_step(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(one_loss)(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(mb_step, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / ga, gsum)
            loss = lsum / ga
        else:
            loss, grads = jax.value_and_grad(one_loss)(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        if acfg.parallel.grad_compression == "int8" and mesh is not None \
                and "pod" in mesh.axis_names:
            from repro.distributed.gradsync import int8_psum_grads
            grads = int8_psum_grads(grads, mesh)

        updates, opt_state = opt.update(grads, state.opt_state, params,
                                        state.step)
        params = apply_updates(params, updates)

        buffers, grams = state.dmd_buffers, state.dmd_gram
        if dmd_on and buffers is not None:
            streaming = streaming_on and grams is not None
            plans = acc.plans_for(params)       # trace-time, cached
            table = acc.arena_for(params)       # {} when arenas are off
            slots = sched_mod.slots_for_step(acc.groups, step)
            # per-leaf snapshot/Gram calls only see the non-packed leaves;
            # with resident params that is the wrapper's leaf subtree
            # (None at every packed path — compile-time pass-throughs)
            p_leaf = (arena_mod.split_state(params)[1] if resident
                      else params)

            # One cond per schedule group: group gi's leaves are written
            # only while gi records (its slot >= 0); other groups' leaves
            # are compile-time pass-throughs inside the branch, so XLA
            # sees the same single-cond program as before for one group.
            # Arena'd leaves ride the packed route (one gather + one row
            # update + one segmented Gram launch per bucket); the per-leaf
            # code below only sees the leaves the arena could not take.
            for gi in range(len(acc.groups)):
                def write(args, gi=gi):
                    bufs, g = args
                    slot = jnp.maximum(slots[gi], 0)
                    if arena_mod.is_arena_state(bufs):
                        arenas, leaf = arena_mod.split_state(bufs)
                        arenas = arena_mod.record(arenas, params, slot,
                                                  table, acfg.dmd, group=gi)
                        leaf = snap.record(leaf, p_leaf, slot, plans,
                                           group=gi)
                        bufs = arena_mod.make_state(arenas, leaf)
                        if streaming:
                            ag, lg = arena_mod.split_state(g)
                            g = arena_mod.make_state(
                                arena_mod.update_grams(ag, arenas, slot,
                                                       acfg.dmd, table,
                                                       group=gi),
                                snap.update_grams(lg, leaf, p_leaf, slot,
                                                  acfg.dmd, plans, group=gi))
                        return bufs, g
                    bufs = snap.record(bufs, params, slot, plans, group=gi)
                    if streaming:
                        g = snap.update_grams(g, bufs, params, slot,
                                              acfg.dmd, plans, group=gi)
                    return bufs, g
                buffers, grams = jax.lax.cond(slots[gi] >= 0, write,
                                              lambda a: a, (buffers, grams))

        new_state = TrainState(params, opt_state, state.step + 1, buffers,
                               grams, state.controller)
        gnorm = jnp.sqrt(sum(jnp.vdot(g, g)
                             for g in jax.tree_util.tree_leaves(grads)))
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def reset_opt_state_after_jump(opt, opt_state, params, plans, groups,
                               n_groups, arena=None):
    """Post-jump optimizer-moment reset.

    `groups` is the set of group indices whose moments should reset
    (callers filter by each group's ``reset_opt`` flag —
    DMDAccelerator.reset_groups). When that covers every group this is the
    legacy full ``opt.init`` — bit-exact with the pre-refactor behavior.
    Otherwise (staggered schedule, or reset-exempt groups), reset ONLY
    those groups' leaves' entries in each params-shaped field of the
    optimizer state: a staggered jump must not clobber the moments the
    other groups are accumulating mid-window. Fields that do not mirror
    the param pytree (scalar counters, empty states) are kept as-is in the
    masked case.

    With arena-RESIDENT moments the masking unit is the BUCKET, not the
    leaf: a bucket's key embeds its schedule group (core/arena.py), so
    every segment of ``arena[key]`` belongs to the same group and a
    whole-buffer swap for ``group in gset`` buckets is exactly the
    group-masked reset — a leaf-granularity mask over the flat buffer
    would either clobber other groups' segments or miss its own. `arena`
    (the accelerator's bucket table) is required when the state is
    resident; callers pass ``arena=acc.arena_for(params)``.
    """
    if groups is None or len(frozenset(groups)) >= n_groups:
        return opt.init(params)
    fresh = opt.init(params)
    pdef = jax.tree_util.tree_structure(params)
    gset = frozenset(int(g) for g in groups)

    def merge_leaf(old_field, new_field):
        return jax.tree_util.tree_map(
            lambda plan, o, n: n if (plan is not None and plan.group in gset)
            else o,
            plans, old_field, new_field, is_leaf=leafplan.is_plan_leaf)

    def merge(old_field, new_field):
        if arena_mod.is_arena_state(old_field):
            if arena is None:
                raise ValueError(
                    "resident optimizer state but no bucket table — pass "
                    "arena=acc.arena_for(params)")
            ares_o, leaf_o = arena_mod.split_state(old_field)
            ares_n, leaf_n = arena_mod.split_state(new_field)
            ares = {k: (ares_n[k] if arena[k].group in gset else v)
                    for k, v in ares_o.items()}
            return arena_mod.make_state(ares, merge_leaf(leaf_o, leaf_n))
        if jax.tree_util.tree_structure(old_field) != pdef:
            return old_field
        return merge_leaf(old_field, new_field)

    if arena_mod.is_arena_state(opt_state) \
            or jax.tree_util.tree_structure(opt_state) == pdef:
        return merge(opt_state, fresh)            # momentum-style state
    if isinstance(opt_state, tuple):              # NamedTuple of field trees
        return type(opt_state)(*(merge(o, n)
                                 for o, n in zip(opt_state, fresh)))
    return opt_state


def audit_step_fns(model, acfg, *, mesh=None,
                   acc: Optional[DMDAccelerator] = None,
                   loss_fn: Callable = None, donate: bool = True):
    """The static-audit surface (repro.audit.targets): every jitted hot
    entry point, under the Trainer's EXACT jit contract (same
    donate_argnums, same static argnames), plus the shared accelerator.

    Returns ``(acc, {name: jitted_fn})`` with
      * ``train_step``     — the fused step (record+Gram riding inside),
      * ``dmd_step``       — the jump in whichever variant the config
                             selects (plain or loss-gated controller),
      * ``record_update``  — record + streaming-Gram maintenance as a
                             standalone program (buffers AND grams
                             donated), so the data-pass invariants are
                             auditable in isolation from the model's
                             forward/backward.

    ``donate=False`` drops every donate_argnums — the seeded-violation
    fixture the donation pass must catch (audit ``--mutate
    drop-donation`` and the CI mutation test)."""
    acc = _accelerator_for(model, acfg, mesh, acc)
    dn = (0,) if donate else ()
    fns = {
        "train_step": jax.jit(
            make_train_step(model, acfg, mesh=mesh, loss_fn=loss_fn,
                            acc=acc), donate_argnums=dn),
        "dmd_step": jax.jit(
            make_dmd_step(acfg, mesh=mesh, acc=acc, model=model,
                          loss_fn=loss_fn), donate_argnums=dn,
            static_argnames=("groups",)),
    }

    def record_update(buffers, grams, params, slots):
        return acc.record(buffers, params, slots, grams)

    fns["record_update"] = jax.jit(record_update,
                                   donate_argnums=(0, 1) if donate else ())
    return acc, fns


def make_dmd_step(acfg, *, mesh=None, acc: Optional[DMDAccelerator] = None,
                  model=None, loss_fn: Callable = None):
    """Returns the paper's jump as a jittable step. Two variants:

      * controller OFF (default): dmd_step(state, relax, groups=None) —
        the ungated jump, VERBATIM the pre-controller path (bit-exact;
        pinned by the fused-step oracle in tests/test_trainer.py).
      * controller ON (cfg.controller.enabled): dmd_step(state, relax,
        eval_batch, groups=None) — the loss-gated jump
        (core/controller.py, DESIGN.md §5): one candidate jump at the
        controller's adapted per-group horizon (ridge-shrunk by the
        meta-tuned per-group ridge when meta_lr > 0), then an in-trace
        gate on the `eval_batch` loss — the caller must pass a VALIDATION
        batch disjoint from the training stream (train/loop.py carves
        one). Accept / shrinkage line search over cfg.controller
        .shrink_levels (re-blends of the same solved jump — no extra
        solves) / reject with bit-exact rollback (pre-jump params and
        moments pass through untouched; buffers, Gram, and the schedule's
        cooldown arithmetic were never disturbed). With meta_lr > 0 a
        final backward through the jump meta-tunes relax_eff/ridge_eff
        (core/controller.py::meta_update). Needs `model` or `loss_fn` for
        the gate forwards.

    `groups` is a STATIC tuple of schedule-group indices to jump (the
    Trainer passes acc.apply_groups(step) and jits it as a static argname);
    None jumps every group — the legacy single-window call. `relax` is a
    scalar or the per-group vector from acc.relax_vector.
    """
    cfg = acfg.dmd
    opt = make_optimizer(acfg.optimizer)
    acc = _accelerator_for(model, acfg, mesh, acc)
    streaming_on = acc.streaming

    if not acc.controller_on:
        def dmd_step(state: TrainState, relax,
                     groups: Optional[Sequence[int]] = None) -> tuple:
            if state.dmd_buffers is None:
                return state, {"mean_rank": jnp.zeros((), jnp.float32)}
            grams = state.dmd_gram
            if grams is None or not streaming_on:
                grams = _none_like(state.dmd_buffers)
            plans = acc.plans_for(state.params)
            params, mean_rank = jump_tree(cfg, plans, state.params,
                                          state.dmd_buffers, grams, relax,
                                          groups=groups,
                                          arena=acc.arena_for(state.params))
            opt_state = state.opt_state
            # the jump teleports the jumped groups' weights; reset those
            # groups' moments — unless the group opts out (sched.reset_opt)
            reset = acc.reset_groups(groups)
            if reset:
                opt_state = reset_opt_state_after_jump(
                    opt, state.opt_state, params, plans, reset, acc.n_groups,
                    arena=acc.arena_for(params))
            new_state = TrainState(params, opt_state, state.step,
                                   state.dmd_buffers, state.dmd_gram,
                                   state.controller)
            return new_state, {"mean_rank": mean_rank}

        return dmd_step

    # ---- loss-gated controller variant ------------------------------------
    from repro.core import controller as ctrl_mod

    ccfg = cfg.controller
    if loss_fn is None and model is None:
        raise ValueError("controller mode needs `model` or `loss_fn` for "
                         "the gate's held-out-loss forwards")
    _loss = loss_fn or (lambda p, b: model.loss(p, b)[0])
    levels = tuple(float(f) for f in
                   (getattr(ccfg, "shrink_levels", (0.5,)) or (0.5,)))
    for f in levels:
        if not 0.0 < f < 1.0:
            raise ValueError(f"controller shrink_levels must lie in (0, 1): "
                             f"got {levels}")
    # Meta-tuning differentiates THROUGH the jump: matpow is plain traced
    # linear algebra, but eig mode routes the operator power through a host
    # pure_callback with no JVP.
    meta_on = float(getattr(ccfg, "meta_lr", 0.0)) > 0
    if meta_on and cfg.mode != "matpow":
        raise ValueError("controller meta-tuning (meta_lr > 0) needs "
                         "dmd.mode='matpow' — the eig host callback is not "
                         "differentiable")

    def gated_dmd_step(state: TrainState, relax, eval_batch,
                       groups: Optional[Sequence[int]] = None) -> tuple:
        zero = jnp.zeros((), jnp.float32)
        if state.dmd_buffers is None:
            return state, {"mean_rank": zero, "ctrl_outcome":
                           jnp.zeros((), jnp.int32), "ctrl_loss_pre": zero,
                           "ctrl_loss_jump": zero, "ctrl_loss_kept": zero,
                           "ctrl_gain": zero, "ctrl_level": zero}
        grams = state.dmd_gram
        if grams is None or not streaming_on:
            grams = _none_like(state.dmd_buffers)
        plans = acc.plans_for(state.params)
        ctrl = state.controller
        jumped = tuple(range(acc.n_groups)) if groups is None \
            else tuple(groups)
        # resident params: the gate forwards see per-leaf views, same
        # in-trace expansion as the fused train step's one_loss
        resident = arena_mod.is_arena_state(state.params)
        table = acc.arena_for(state.params) if resident else None

        def eval_loss(p):
            if resident:
                p = arena_mod.tree_leafwise(table, p)
            return _loss(p, eval_batch)

        # Candidate jump at the adapted horizon, relax tempered by the
        # per-group effective scale. `relax` may be scalar or (n_groups,);
        # the product with relax_eff is always the per-group vector. The
        # meta-tuned ridge_eff only feeds the solve while meta-tuning is on
        # (meta_lr > 0) — with it off the schedule's STATIC per-group ridge
        # applies and the trace is unchanged from the pre-ridge path.
        s_vec = ctrl_mod.effective_s(ctrl, acc.groups, ccfg)
        relax_vec = jnp.broadcast_to(
            jnp.asarray(relax, jnp.float32),
            (acc.n_groups,)) * ctrl.relax_eff
        ridge_vec = ctrl.ridge_eff if meta_on else None
        table_full = acc.arena_for(state.params)
        p_jump, mean_rank = jump_tree(cfg, plans, state.params,
                                      state.dmd_buffers, grams, relax_vec,
                                      groups=groups, s_vec=s_vec,
                                      arena=table_full, ridge_vec=ridge_vec)

        loss_pre = eval_loss(state.params)
        loss_post = eval_loss(p_jump)

        reset = acc.reset_groups(groups)

        def reset_moments(params):
            if not reset:
                return state.opt_state
            return reset_opt_state_after_jump(
                opt, state.opt_state, params, plans, reset, acc.n_groups,
                arena=acc.arena_for(params))

        def accept_full(_):
            return p_jump, reset_moments(p_jump), \
                jnp.asarray(ctrl_mod.ACCEPT, jnp.int32), loss_post, \
                jnp.float32(levels[0])

        def blend(f):
            # relax enters the coefficients linearly, so the blend
            # f*w_jump + (1-f)*w_pre IS the f-scaled-relax jump — no second
            # coefficient solve, one extra gate forward per tried rung
            # (paid only inside its branch).
            return jax.tree_util.tree_map(
                lambda a, b: ((1.0 - f) * a.astype(jnp.float32)
                              + f * b.astype(jnp.float32)).astype(a.dtype),
                state.params, p_jump)

        def reject(_):
            # Bit-exact rollback: the donated pre-jump params and
            # moments pass straight through; buffers / Gram / schedule
            # cooldown were never touched by the jump.
            return state.params, state.opt_state, \
                jnp.asarray(ctrl_mod.REJECT, jnp.int32), loss_pre, \
                jnp.float32(levels[0])

        def try_levels(idx):
            # Shrinkage line search (DESIGN.md §5): nested conds over the
            # static shrink_levels ladder — each rung re-blends the SAME
            # solved jump at a smaller fraction and keeps the first one the
            # gate accepts; falling off the ladder is the rollback. The
            # default single rung (0.5,) is the legacy blind halving.
            if idx >= len(levels):
                return reject
            f = levels[idx]

            def attempt(_):
                p_lvl = blend(f)
                loss_lvl = eval_loss(p_lvl)

                def accept_lvl(_):
                    return p_lvl, reset_moments(p_lvl), \
                        jnp.asarray(ctrl_mod.SCALED, jnp.int32), loss_lvl, \
                        jnp.float32(f)

                return jax.lax.cond(
                    ctrl_mod.gate_outcome(loss_pre, loss_lvl,
                                          ccfg.accept_tol),
                    accept_lvl, try_levels(idx + 1), None)

            return attempt

        params, opt_state, outcome, loss_final, level = jax.lax.cond(
            ctrl_mod.gate_outcome(loss_pre, loss_post, ccfg.accept_tol),
            accept_full, try_levels(0), None)

        gain = (loss_pre - loss_final) / jnp.maximum(loss_pre, 1e-30)
        new_ctrl = ctrl_mod.update_on_jump(ctrl, jumped, outcome, gain,
                                           ccfg, acc.groups, level=level)
        if meta_on:
            # Weiner & Semaan meta-tuning: the gate loss differentiated
            # THROUGH the jump wrt a per-group relax scale (at 1) and the
            # ridge knob; meta_update EMAs relax_eff/ridge_eff toward the
            # descent direction. One extra backward per gate round — the
            # Gram, eigh, and buffers are all shared with the candidate.
            def meta_loss(knobs):
                rscale, rknob = knobs
                pv, _ = jump_tree(cfg, plans, state.params,
                                  state.dmd_buffers, grams,
                                  relax_vec * rscale, groups=groups,
                                  s_vec=s_vec, arena=table_full,
                                  ridge_vec=rknob)
                return eval_loss(pv)

            g_relax, g_ridge = jax.grad(meta_loss)(
                (jnp.ones((acc.n_groups,), jnp.float32), ctrl.ridge_eff))
            new_ctrl = ctrl_mod.meta_update(new_ctrl, jumped, g_relax,
                                            g_ridge, ccfg, acc.groups)
        new_state = TrainState(params, opt_state, state.step,
                               state.dmd_buffers, state.dmd_gram, new_ctrl)
        # telemetry: `ctrl_loss_jump` is the FULL candidate's eval loss,
        # `ctrl_loss_kept` the loss of whatever was kept (== loss_jump on
        # accept, the winning blend's loss on a scale-back, loss_pre on a
        # rollback), `ctrl_level` the realized line-search fraction — gain
        # is computed from `kept`, so the trio is always self-consistent.
        return new_state, {"mean_rank": mean_rank, "ctrl_outcome": outcome,
                           "ctrl_loss_pre": loss_pre,
                           "ctrl_loss_jump": loss_post,
                           "ctrl_loss_kept": loss_final, "ctrl_gain": gain,
                           "ctrl_level": level}

    return gated_dmd_step
