"""Training state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    step: jnp.ndarray          # scalar int32
    dmd_buffers: PyTree        # snapshot buffers (None when DMD disabled)
    dmd_gram: PyTree = None    # running (stack..., m, m) fp32 Grams per
                               # buffer leaf (None unless dmd.streaming_gram)
    controller: PyTree = None  # per-group jump-controller state
                               # (core/controller.py ControllerState of tiny
                               # (n_groups,) arrays; None unless
                               # dmd.controller.enabled). Checkpointed and
                               # resharded with the rest of the state, so a
                               # preemption on the exact jump step resumes
                               # counters / s_eff / cooldown bit-exactly.
