from repro.data.tokens import synthetic_lm_batches, batch_for_step
from repro.data import pollutant

__all__ = ["synthetic_lm_batches", "batch_for_step", "pollutant"]
