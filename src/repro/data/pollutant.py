"""The paper's dataset: dispersion of a reactive pollutant in the atmosphere
(Appendix 1), re-implemented end-to-end.

Pipeline:
  1. Blasius boundary layer with slip: solve 2f''' + f'' f = 0,
     f'(0)=uh/U0, f(0)=-2uv/sqrt(nu U0), f'(inf)=1 by shooting (RK4 +
     secant on f''(0)), giving the velocity field
       u_x = U0 f'(eta),  u_y = 0.5 sqrt(nu U0 / x) (eta f' - f),
       eta = y sqrt(U0/(2 nu x)).
  2. Steady advection-diffusion-reaction system for (c1, c2, c3) on a
     uniform nx x ny grid — upwind advection, central diffusion,
     pseudo-time marching to steady state (explicit, CFL-limited), Picard
     treatment of the bilinear reaction term, vmapped over parameter samples:
       u.grad c1 - D lap c1 + K12 c1 c2 = Q1
       u.grad c2 - D lap c2 + K12 c1 c2 = Q2
       u.grad c3 - D lap c3 + K3 c3     = K12 c1 c2
     (The paper's eq. (8) signs are typeset inconsistently with its own text;
     we implement the physical reading: reactants consumed, pollutant
     produced then decaying — matching the paper's Fig. 2 phenomenology.)
  3. LHS sampling of the 6 uncertain params over the paper's ranges; targets
     are c3 at 2670 probe points biased toward the source/ground (paper §4);
     inputs/outputs normalized.

Boundary conditions: inflow c=0 at x=0, outflow dc/dx=0 at x=Lx, Neumann at
the terrain (y=0) and top. Sources: discs of radius 0.5 at (0.1, 0.1) and
(0.1, 0.3) with strength 0.1 (paper eq. 9).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

NU = 1e-5                       # kinematic viscosity of air (paper)

PARAM_RANGES = {
    "K12": (1.0, 20.0),
    "K3": (0.0, 10.0),
    "D": (0.01, 0.5),
    "U0": (0.01, 2.0),
    "uh": (-0.2, 0.2),
    "uv": (-0.2, 0.2),
}
PARAM_ORDER = ("K12", "K3", "D", "U0", "uh", "uv")


# ---------------------------------------------------------------------------
# 1. Blasius with slip (shooting method)
# ---------------------------------------------------------------------------

def _blasius_integrate(fpp0: float, fp0: float, f0: float,
                       eta_max: float = 10.0, n: int = 400):
    """RK4 integrate [f, f', f''] with 2f''' = -f'' f. Returns trajectory."""
    h = eta_max / n
    y = np.array([f0, fp0, fpp0], dtype=np.float64)

    def rhs(y):
        return np.array([y[1], y[2], -0.5 * y[2] * y[0]])

    traj = [y.copy()]
    with np.errstate(all="ignore"):
        for _ in range(n):
            k1 = rhs(y)
            k2 = rhs(y + 0.5 * h * k1)
            k3 = rhs(y + 0.5 * h * k2)
            k4 = rhs(y + h * k3)
            y = y + (h / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)
            y = np.clip(np.nan_to_num(y, nan=1e6, posinf=1e6, neginf=-1e6),
                        -1e6, 1e6)
            traj.append(y.copy())
    return np.stack(traj)          # (n+1, 3)


def solve_blasius(U0: float, uh: float, uv: float,
                  eta_max: float = 10.0, n: int = 400):
    """Shooting on f''(0) so that f'(eta_max) = 1. Returns (eta, f, fp)."""
    # Slip BCs per Appendix 1; clipped to the regime where the self-similar
    # profile stays physical (extreme corners of the LHS box, e.g. U0 -> 0.01
    # with |uv| = 0.2, give |f(0)| ~ 1e3 where the Blasius ansatz breaks).
    fp0 = np.clip(uh / max(U0, 1e-8), -0.5, 1.5)
    f0 = np.clip(-2.0 * uv / np.sqrt(NU * max(U0, 1e-8)), -2.0, 2.0)

    def shoot(fpp0):
        val = _blasius_integrate(fpp0, fp0, f0, eta_max, n)[-1, 1] - 1.0
        return float(np.clip(np.nan_to_num(val, nan=10.0), -10.0, 10.0))

    a, b = 0.0, 2.0
    fa, fb = shoot(a), shoot(b)
    tries = 0
    while fa * fb > 0 and tries < 12:
        b *= 2.0
        fb = shoot(b)
        tries += 1
    if fa * fb > 0:                  # fallback: standard Blasius value
        fpp0 = 0.4696
    else:
        for _ in range(60):          # bisection
            mid = 0.5 * (a + b)
            fm = shoot(mid)
            if fa * fm <= 0:
                b, fb = mid, fm
            else:
                a, fa = mid, fm
        fpp0 = 0.5 * (a + b)
    traj = _blasius_integrate(fpp0, fp0, f0, eta_max, n)
    eta = np.linspace(0.0, eta_max, n + 1)
    return eta, traj[:, 0], traj[:, 1]


def velocity_field(U0, uh, uv, X, Y):
    """Evaluate (u_x, u_y) on grid arrays X, Y (same shape)."""
    eta_grid, f_tab, fp_tab = solve_blasius(U0, uh, uv)
    x_safe = np.maximum(X, 1e-3)
    eta = Y * np.sqrt(max(U0, 1e-8) / (2.0 * NU * x_safe))
    eta_c = np.clip(eta, 0.0, eta_grid[-1])
    fp = np.interp(eta_c, eta_grid, fp_tab)
    f = np.interp(eta_c, eta_grid, f_tab)
    ux = fp * U0
    uy = 0.5 * np.sqrt(NU * max(U0, 1e-8) / x_safe) * (eta_c * fp - f)
    return ux.astype(np.float32), uy.astype(np.float32)


# ---------------------------------------------------------------------------
# 2. Steady transport solve (jax, vmapped over samples)
# ---------------------------------------------------------------------------

def make_grid(nx: int = 96, ny: int = 48, lx: float = 2.0, ly: float = 1.0):
    x = np.linspace(0.0, lx, nx)
    y = np.linspace(0.0, ly, ny)
    X, Y = np.meshgrid(x, y, indexing="ij")
    return X.astype(np.float32), Y.astype(np.float32)


def source_fields(X, Y):
    q1 = np.where((X - 0.1) ** 2 + (Y - 0.1) ** 2 < 0.25, 0.1, 0.0)
    q2 = np.where((X - 0.1) ** 2 + (Y - 0.3) ** 2 < 0.25, 0.1, 0.0)
    return q1.astype(np.float32), q2.astype(np.float32)


@functools.partial(jax.jit, static_argnames=("n_iter",))
def steady_transport(ux, uy, D, K12, K3, q1, q2, dx, dy,
                     n_iter: int = 20000, tol: float = 1e-5):
    """Pseudo-time march the 3-species system to steady state.

    LOCAL time stepping (per-cell CFL limit) — only the steady state matters,
    so each cell marches at its own maximal stable rate; converges ~10-50x
    faster than a global dt when U0 spans [0.01, 2]. Terminates on the PDE
    residual (max |dc/dtau| < tol) with an n_iter safety cap.

    All inputs are per-sample; vmap over the leading axis for batches.
    Returns (c1, c2, c3) fields of shape (nx, ny).
    """
    ux = jnp.nan_to_num(ux)
    uy = jnp.nan_to_num(uy)

    def upwind_grad(c):
        dcdx_m = (c - jnp.roll(c, 1, axis=0)) / dx
        dcdx_p = (jnp.roll(c, -1, axis=0) - c) / dx
        dcdy_m = (c - jnp.roll(c, 1, axis=1)) / dy
        dcdy_p = (jnp.roll(c, -1, axis=1) - c) / dy
        adv_x = jnp.where(ux > 0, ux * dcdx_m, ux * dcdx_p)
        adv_y = jnp.where(uy > 0, uy * dcdy_m, uy * dcdy_p)
        return adv_x + adv_y

    def lap(c):
        d2x = (jnp.roll(c, -1, 0) - 2 * c + jnp.roll(c, 1, 0)) / dx ** 2
        d2y = (jnp.roll(c, -1, 1) - 2 * c + jnp.roll(c, 1, 1)) / dy ** 2
        return d2x + d2y

    def apply_bc(c):
        c = c.at[0, :].set(0.0)                 # inflow
        c = c.at[-1, :].set(c[-2, :])           # outflow
        c = c.at[:, 0].set(c[:, 1])             # terrain Neumann
        c = c.at[:, -1].set(c[:, -2])           # top Neumann
        return c

    # per-cell stable pseudo-step; the reaction bound uses the source-scale
    # concentration cap (c <= 0.1 * advective residence time, bounded below)
    base = (jnp.abs(ux) / dx + jnp.abs(uy) / dy
            + 2.0 * D * (1.0 / dx ** 2 + 1.0 / dy ** 2))
    cmax = 2.0
    dt_loc = 0.7 / (base + K12 * cmax + K3 + 1e-3)

    def body(state):
        c1, c2, c3, it, res = state
        r = K12 * c1 * c2
        dc1 = -upwind_grad(c1) + D * lap(c1) - r + q1
        dc2 = -upwind_grad(c2) + D * lap(c2) - r + q2
        dc3 = -upwind_grad(c3) + D * lap(c3) + r - K3 * c3
        c1n = apply_bc(jnp.clip(c1 + dt_loc * dc1, 0.0, cmax))
        c2n = apply_bc(jnp.clip(c2 + dt_loc * dc2, 0.0, cmax))
        c3n = apply_bc(jnp.clip(c3 + dt_loc * dc3, 0.0, cmax))
        res = jnp.maximum(jnp.max(jnp.abs(c1n - c1)),
                          jnp.maximum(jnp.max(jnp.abs(c2n - c2)),
                                      jnp.max(jnp.abs(c3n - c3))))
        return c1n, c2n, c3n, it + 1, res

    def cond(state):
        _, _, _, it, res = state
        return (it < n_iter) & (res > tol)

    z = jnp.zeros(q1.shape, jnp.float32)
    c1, c2, c3, _, _ = jax.lax.while_loop(
        cond, body, (z, z, z, jnp.zeros((), jnp.int32),
                     jnp.ones((), jnp.float32)))
    return c1, c2, c3


# ---------------------------------------------------------------------------
# 3. LHS sampling + dataset assembly
# ---------------------------------------------------------------------------

def latin_hypercube(n: int, dims: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    u = (rng.permutation(n)[:, None] if dims == 1 else
         np.stack([rng.permutation(n) for _ in range(dims)], axis=1))
    return (u + rng.uniform(size=(n, dims))) / n


def sample_params(n: int, seed: int = 0) -> np.ndarray:
    """(n, 6) array in physical units, LHS over the paper's ranges."""
    unit = latin_hypercube(n, len(PARAM_ORDER), seed)
    cols = []
    for j, name in enumerate(PARAM_ORDER):
        lo, hi = PARAM_RANGES[name]
        cols.append(lo + unit[:, j] * (hi - lo))
    return np.stack(cols, axis=1).astype(np.float32)


def probe_points(n_points: int = 2670, seed: int = 1,
                 lx: float = 2.0, ly: float = 1.0) -> np.ndarray:
    """Probe locations biased toward the source / ground (paper §4)."""
    rng = np.random.default_rng(seed)
    n_src = n_points // 2
    n_gnd = n_points - n_src
    px_s = 0.1 + rng.exponential(0.35, n_src)
    py_s = 0.1 + rng.exponential(0.18, n_src) * rng.choice([-1, 1], n_src)
    px_g = rng.uniform(0, lx, n_gnd)
    py_g = rng.exponential(0.15, n_gnd)
    px = np.clip(np.concatenate([px_s, px_g]), 0.0, lx)
    py = np.clip(np.abs(np.concatenate([py_s, py_g])), 0.0, ly)
    return np.stack([px, py], axis=1).astype(np.float32)


def generate_dataset(n_samples: int = 1000, nx: int = 96, ny: int = 48,
                     n_points: int = 2670, n_iter: int = 4000,
                     seed: int = 0, batch: int = 32,
                     verbose: bool = False) -> Dict[str, np.ndarray]:
    """Full paper dataset: X (n, 6) normalized params, Y (n, n_points)
    normalized c3 at probes. Velocity fields are per-sample (Blasius on
    host); transport solves are vmapped on device."""
    lx, ly = 2.0, 1.0
    X, Y = make_grid(nx, ny, lx, ly)
    q1, q2 = source_fields(X, Y)
    dx, dy = lx / (nx - 1), ly / (ny - 1)
    params = sample_params(n_samples, seed)
    probes = probe_points(n_points, seed + 1, lx, ly)
    # bilinear sample indices
    gx = np.clip(probes[:, 0] / dx, 0, nx - 1 - 1e-3)
    gy = np.clip(probes[:, 1] / dy, 0, ny - 1 - 1e-3)
    ix, iy = gx.astype(int), gy.astype(int)
    fx, fy = gx - ix, gy - iy

    solve_batch = jax.jit(jax.vmap(
        lambda ux, uy, D, K12, K3: steady_transport(
            ux, uy, D, K12, K3, q1, q2, dx, dy, n_iter=n_iter)))

    outs = []
    for start in range(0, n_samples, batch):
        chunk = params[start:start + batch]
        uxs, uys = [], []
        for K12, K3, D, U0, uh, uv in chunk:
            ux, uy = velocity_field(U0, uh, uv, X, Y)
            uxs.append(ux)
            uys.append(uy)
        c1, c2, c3 = solve_batch(jnp.asarray(np.stack(uxs)),
                                 jnp.asarray(np.stack(uys)),
                                 jnp.asarray(chunk[:, 2]),
                                 jnp.asarray(chunk[:, 0]),
                                 jnp.asarray(chunk[:, 1]))
        c3 = np.asarray(c3)
        vals = ((1 - fx) * (1 - fy) * c3[:, ix, iy]
                + fx * (1 - fy) * c3[:, np.minimum(ix + 1, nx - 1), iy]
                + (1 - fx) * fy * c3[:, ix, np.minimum(iy + 1, ny - 1)]
                + fx * fy * c3[:, np.minimum(ix + 1, nx - 1),
                               np.minimum(iy + 1, ny - 1)])
        outs.append(vals.astype(np.float32))
        if verbose:
            print(f"  solved {min(start + batch, n_samples)}/{n_samples}")
    Yv = np.concatenate(outs, axis=0)                     # (n, n_points)

    # normalize: params to [-1, 1]; outputs scaled to O(1) (paper §4)
    lo = np.array([PARAM_RANGES[k][0] for k in PARAM_ORDER], np.float32)
    hi = np.array([PARAM_RANGES[k][1] for k in PARAM_ORDER], np.float32)
    Xn = 2.0 * (params - lo) / (hi - lo) - 1.0
    scale = max(float(np.std(Yv)), 1e-8)
    Yn = (Yv - float(np.mean(Yv))) / scale
    return {"X": Xn, "Y": Yn, "params_raw": params, "probes": probes,
            "y_mean": np.float32(np.mean(Yv)), "y_scale": np.float32(scale)}


def train_test_split(data: Dict[str, np.ndarray], train_frac: float = 0.8,
                     seed: int = 2):
    n = data["X"].shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    k = int(n * train_frac)
    tr, te = perm[:k], perm[k:]
    return ((data["X"][tr], data["Y"][tr]), (data["X"][te], data["Y"][te]))
