"""Deterministic synthetic LM token pipeline.

Batches are a pure function of (seed, step): a restarted/elastically-resized
worker replays the identical stream — the fault-tolerance contract the
trainer relies on (DESIGN.md §6). Tokens follow a Zipf-ish distribution so
losses behave like text rather than uniform noise.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp


def batch_for_step(seed: int, step: int, global_batch: int, seq_len: int,
                   vocab_size: int, *, mrope: bool = False,
                   frames: Optional[tuple] = None) -> Dict[str, jnp.ndarray]:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k_tok, k_frames = jax.random.split(key)
    # Zipf-ish: exponentiate a uniform to skew token ids low
    u = jax.random.uniform(k_tok, (global_batch, seq_len + 1),
                           minval=1e-6, maxval=1.0)
    ids = (u ** 3.0 * vocab_size).astype(jnp.int32) % vocab_size
    batch = {"tokens": ids[:, :-1], "labels": ids[:, 1:]}
    if mrope:
        pos = jnp.broadcast_to(jnp.arange(seq_len)[None, None, :],
                               (global_batch, 3, seq_len))
        batch["positions"] = pos
    if frames is not None:
        batch["frames"] = jax.random.normal(
            k_frames, (global_batch,) + tuple(frames), jnp.float32)
    return batch


# Reserved stream offset for the validation split (ISSUE 9). The training
# stream indexes batches by optimizer step, so every index a run can reach
# is a TRAINING batch; the validation fold lives past 2^30 steps — disjoint
# from any reachable training index, deterministic, and step-independent
# (a preemption-exact resume sees the identical split).
VAL_FOLD = 1 << 30


def validation_batch(seed: int, global_batch: int, seq_len: int,
                     vocab_size: int, *, index: int = 0,
                     **kw) -> Dict[str, jnp.ndarray]:
    """One deterministic validation batch DISJOINT from the training stream:
    drawn at the reserved ``VAL_FOLD`` offset that ``batch_for_step``'s
    step-indexed training stream never reaches. The jump controller's gate
    scores on this split (train/loop.py) — gating on training rows accepts
    train-overfit jumps. ``index`` selects among multiple validation
    batches."""
    return batch_for_step(seed, VAL_FOLD + index, global_batch, seq_len,
                          vocab_size, **kw)


def synthetic_lm_batches(seed: int, global_batch: int, seq_len: int,
                         vocab_size: int, *, start_step: int = 0,
                         **kw) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield batch_for_step(seed, step, global_batch, seq_len, vocab_size,
                             **kw)
        step += 1
