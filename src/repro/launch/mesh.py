"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int, model_parallel: int = 1,
                          pods: int = 1):
    """Elastic helper: build a (pod, data, model) mesh from whatever device
    count is available (restart-after-resize path)."""
    assert n_devices % (model_parallel * pods) == 0, \
        f"{n_devices} devices not divisible by tp={model_parallel} x pods={pods}"
    data = n_devices // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallel), ("data", "model"))
