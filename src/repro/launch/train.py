"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        [--steps 200] [--ckpt /path] [--reduced] [--no-dmd] [--multi-pod]

On real TPU slices this runs the full config on the production mesh; on this
CPU container use --reduced (same-family shrunk config, 1 device). SIGTERM
triggers a checkpoint-and-exit (preemption handling); rerunning with the
same --ckpt resumes bit-exactly.
"""
import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--no-dmd", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced, shape_by_name
    from repro.data.tokens import synthetic_lm_batches
    from repro.distributed.sharding import mesh_context
    from repro.models.transformer import LanguageModel
    from repro.train import Trainer
    from repro.checkpoint import latest_step

    acfg = get_config(args.arch)
    mc = reduced(acfg.model) if args.reduced else acfg.model
    gb = args.global_batch or (8 if args.reduced else
                               shape_by_name("train_4k").global_batch)
    seq = args.seq or (64 if args.reduced else 4096)
    acfg = dataclasses.replace(
        acfg, model=mc,
        dmd=dataclasses.replace(acfg.dmd, enabled=not args.no_dmd,
                                warmup_steps=min(acfg.dmd.warmup_steps,
                                                 args.steps // 4)),
        train=dataclasses.replace(acfg.train, global_batch=gb, seq_len=seq,
                                  checkpoint_every=50 if args.ckpt else 0,
                                  checkpoint_dir=args.ckpt))

    mesh = None
    if not args.reduced:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    model = LanguageModel(mc, head_tp=not args.reduced,
                          chunk_k=min(seq, 1024),
                          remat=acfg.parallel.remat if not args.reduced
                          else "none",
                          pad_heads_to=acfg.parallel.pad_attn_heads_to)
    print(f"{args.arch}: {model.param_count()/1e6:.1f}M params, "
          f"dmd={'off' if args.no_dmd else 'on'}, batch={gb}x{seq}")

    def run():
        trainer = Trainer(model, acfg, mesh=mesh,
                          checkpoint_dir=args.ckpt or None)
        start = (latest_step(args.ckpt) or 0) if args.ckpt else 0
        batches = synthetic_lm_batches(
            acfg.train.seed, gb, seq, mc.vocab_size, start_step=start,
            mrope=bool(mc.mrope_sections),
            frames=(mc.encoder_seq_len, mc.d_model)
            if mc.family == "encdec" else None)
        trainer.fit(batches, steps=args.steps, log_every=10)

    if mesh is not None:
        with mesh_context(mesh):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
