"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every dry-run
cell — weak-type-correct, shardable, zero device allocation.

Also builds cache specs for decode cells and param/state specs, i.e. the
complete in_shardings for jit(...).lower().
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import normalize_path

PyTree = Any


def _mesh_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _nbatch(mesh: Mesh) -> int:
    s = _mesh_sizes(mesh)
    return s.get("pod", 1) * s.get("data", 1)


# ---------------------------------------------------------------------------
# Model inputs
# ---------------------------------------------------------------------------

def input_specs(acfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
                ) -> Tuple[PyTree, PyTree]:
    """Returns (abstract batch pytree, matching PartitionSpec pytree)."""
    mc = acfg.model
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    ba = batch_axes(mesh)
    b_spec = ba if B % _nbatch(mesh) == 0 else None

    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    specs["tokens"] = P(b_spec, None)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = P(b_spec, None)
    if mc.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, mc.encoder_seq_len, mc.d_model), jnp.float32)
        specs["frames"] = P(b_spec, None, None)
    if mc.mrope_sections:
        batch["positions"] = jax.ShapeDtypeStruct((B, 3, S), jnp.int32)
        specs["positions"] = P(b_spec, None, None)
    return batch, specs


def gate_batch_specs(batch: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpecs for the controller's gate/validation microbatch
    (ISSUE 9): leading batch axis over the data axes when divisible,
    everything else replicated — the same placement ``input_specs`` gives
    training batches, but derived from a CONCRETE batch pytree (the
    validation split is carved host-side at trainer init, not dry-run from
    a ShapeConfig cell, and may be row-clamped by controller.eval_rows)."""
    nb = _nbatch(mesh)
    ba = batch_axes(mesh)

    def one(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return P()
        b = ba if leaf.shape[0] % nb == 0 else None
        return P(*((b,) + (None,) * (nd - 1)))

    return jax.tree_util.tree_map(one, batch)


def gate_batch_shardings(batch: PyTree, mesh: Mesh) -> PyTree:
    """NamedShardings for the gate batch (see gate_batch_specs)."""
    return shardings_of(gate_batch_specs(batch, mesh), mesh)


# ---------------------------------------------------------------------------
# Cache specs (decode / prefill cells)
# ---------------------------------------------------------------------------

def cache_partition_specs(caches: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec per cache leaf, by path suffix + divisibility."""
    sizes = _mesh_sizes(mesh)
    tp = sizes.get("model", 1)
    nb = _nbatch(mesh)
    ba = batch_axes(mesh)

    def spec(path, leaf) -> P:
        p = normalize_path(jax.tree_util.keystr(path))
        shape = leaf.shape
        nd = len(shape)
        if p.endswith("/length") or p.endswith("/pos"):
            return P()
        if p.endswith("/h"):                       # (stack.., B, H, Pd, N)
            lead = (None,) * (nd - 4)
            B, H, Pd, N = shape[-4:]
            if B % nb == 0:
                return P(*lead, ba, "model" if H % tp == 0 else None,
                         None, None)
            return P(*lead, None, "model" if H % tp == 0 else None,
                     "data" if Pd % sizes.get("data", 1) == 0 else None, None)
        if p.endswith("/conv_x") or p.endswith("/conv_B") or p.endswith("/conv_C"):
            lead = (None,) * (nd - 3)
            B, W, C = shape[-3:]
            return P(*lead, ba if B % nb == 0 else None, None,
                     "model" if C % tp == 0 else None)
        if p.endswith("/k") or p.endswith("/v") or "cross_" in p:
            # (stack.., B, S, K, hd)
            lead = (None,) * (nd - 4)
            B, S, K, hd = shape[-4:]
            b = ba if B % nb == 0 else None
            k_tp = "model" if (K % tp == 0 and K >= tp) else None
            dsize = sizes.get("data", 1)
            if b is None:
                # B unshardable (long_500k B=1): spread S over free axes
                if k_tp and S % dsize == 0:
                    return P(*lead, None, "data", k_tp, None)
                if not k_tp and S % (dsize * tp) == 0:
                    return P(*lead, None, ("data", "model"), None, None)
                return P(*lead, None, None, k_tp, None)
            if k_tp:
                return P(*lead, b, None, k_tp, None)
            if S % tp == 0:
                return P(*lead, b, "model", None, None)
            return P(*lead, b, None, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec, caches)


def state_specs(state_tree: PyTree, mesh: Mesh,
                plans: Optional[PyTree] = None,
                arena: Optional[dict] = None) -> PyTree:
    """Specs for a TrainState: params/opt/dmd follow param rules; step = ().

    When the accelerator's LeafPlan pytree is given, DMD buffer and Gram
    specs come from the plan table (plan.snapshot_spec / plan.gram_spec — the
    single audited source, DESIGN.md §3/§6) instead of being re-derived from
    the path rules. Both derivations agree; the plan is authoritative.
    Specs are shape-agnostic, so heterogeneous per-group windows (a mixed-m
    schedule sizes each leaf's buffer (m_leaf, ...) — DESIGN.md §4) need no
    special casing: the snapshot axis is replicated whatever its length.

    Arena states (DESIGN.md §7) carry per-bucket leaves under
    ``/dmd_buffers/__arena__/<key>`` — their block-major
    (n_blocks, m, block_n) ring buffers shard the lane axes over the
    leading BLOCK dim by the bucket's buffer_spec (replicated for
    unsharded buckets), the (n_sys, m, m) Gram stacks follow the bucket's
    gram_spec (replicated, except system-sharded buckets which stay
    sharded over their sys_axes), and the per-leaf remainder lives under
    ``/leaf`` with the plan-derived specs. `arena` is the accelerator's
    bucket table (``acc.arena_for(params)``).

    Arena-RESIDENT params/moments (dmd.arena_native) add the same wrapper
    under ``/params`` and the opt_state's moment fields: the flat ``(N,)``
    buckets take the 1-D lane_spec, the ``/leaf`` remainder keeps the
    per-leaf param rules (with the wrapper's path segment stripped so the
    rules still match).
    """
    from repro.core.arena import ARENA_KEY, is_arena_state
    from repro.core.leafplan import plan_entries
    from repro.distributed.sharding import resolve_rule, rule_for_path

    plan_by_path = ({pl.path: pl for pl in plan_entries(plans)}
                    if plans is not None else {})
    arena = arena or {}
    # Only an arena-layout state has the {"__arena__", "leaf"} wrapper; a
    # per-leaf state whose PARAM pytree happens to contain a key literally
    # named "leaf" must NOT have that path segment stripped.
    arena_layout = is_arena_state(getattr(state_tree, "dmd_buffers", None))
    param_resident = is_arena_state(getattr(state_tree, "params", None))

    def _bucket_of(key: str):
        if key not in arena:
            # Failing loudly beats a silent replication cliff: marking a
            # lane-sharded ring buffer replicated would device_put the
            # full multi-GiB arena onto EVERY device with no error.
            raise ValueError(
                f"arena-layout state has bucket {key!r} but no matching "
                "entry in the bucket table — pass arena="
                "acc.arena_for(params) to state_specs (and rebuild it "
                "after any plan-table change)")
        return arena[key]

    def _bucket_spec(sub: str, grams: bool) -> Optional[P]:
        """Spec for an ``/__arena__/<key>`` leaf, None for non-arena paths."""
        if not arena_layout or not sub.startswith(f"/{ARENA_KEY}/"):
            return None
        b = _bucket_of(sub[len(ARENA_KEY) + 2:])
        if grams:
            return b.gram_spec()          # replicated unless sys-sharded
        return b.buffer_spec()            # block-major snapshot buffer

    def _strip_leaf(sub: str) -> str:
        if arena_layout and sub.startswith("/leaf/"):
            return sub[len("/leaf"):]
        return sub

    def one(path, leaf):
        p = normalize_path(jax.tree_util.keystr(path))
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if p.startswith("/dmd_buffers"):
            sub = p.split("/dmd_buffers", 1)[1]
            spec = _bucket_spec(sub, grams=False)
            if spec is not None:
                return spec
            sub = _strip_leaf(sub)
            pl = plan_by_path.get(sub)
            if pl is not None:
                return pl.snapshot_spec
            return _param_spec_of(sub, leaf, mesh, lead=1)
        if p.startswith("/dmd_gram"):
            sub = p.split("/dmd_gram", 1)[1]
            spec = _bucket_spec(sub, grams=True)
            if spec is not None:
                return spec
            pl = plan_by_path.get(_strip_leaf(sub))
            if pl is not None:
                return pl.gram_spec
            return P()          # (stack..., m, m) running Grams: O(m^2) bytes,
                                # replicated (the psum'd reduction of the
                                # sharded row pass — DESIGN.md §2)
        if "/opt_state/vr/" in p or "/opt_state/vc/" in p:
            # adafactor factored moments: vr drops the param's last dim,
            # vc drops the second-to-last.
            rule = rule_for_path(p)
            if rule is not None and len(rule) >= 2:
                rule = rule[:-1] if "/vr/" in p else rule[:-2] + rule[-1:]
            return resolve_rule(rule, nd, leaf.shape, mesh)
        if p.startswith("/params") or p.startswith("/opt_state"):
            if param_resident:
                if f"/{ARENA_KEY}/" in p:
                    # resident flat (N,) bucket: the 1-D lane spec
                    b = _bucket_of(p.split(f"/{ARENA_KEY}/", 1)[1])
                    return b.lane_spec()
                p = p.replace("/leaf/", "/", 1)   # wrapper's leaf subtree
            return _param_spec_of(p, leaf, mesh)
        return P()
    return jax.tree_util.tree_map_with_path(one, state_tree)


def _param_spec_of(path: str, leaf, mesh: Mesh, lead: int = 0) -> P:
    from repro.distributed.sharding import spec_for_path
    nd = len(leaf.shape) - lead
    base = spec_for_path(path, nd, mesh, leaf.shape[lead:])
    if lead:
        return P(*((None,) * lead + tuple(base)))
    return base


def shardings_of(tree_specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Serving engine (repro.serve)
# ---------------------------------------------------------------------------

def param_specs(params: PyTree, mesh: Mesh) -> PyTree:
    """Per-leaf PartitionSpecs for a bare (leaf-wise) param pytree — the
    serving/publish template placement. Same path rules the training
    state uses (state_specs' ``/params`` branch), without the TrainState
    wrapper: this is what a ParamStore's ``shardings=`` wants after the
    trainer's ``acc.params_leafwise`` export."""
    def one(path, leaf):
        p = normalize_path(jax.tree_util.keystr(path))
        if len(getattr(leaf, "shape", ())) == 0:
            return P()
        return _param_spec_of(p, leaf, mesh)
    return jax.tree_util.tree_map_with_path(one, params)


def serve_state_specs(dstate: PyTree, mesh: Mesh) -> PyTree:
    """Specs for the serve engine's slot-stacked decode state
    (repro.serve.engine.ServeEngine._dstate).

    The leading ``(n_slots,)`` axis IS the serving data parallelism —
    continuous batching shards the slot table over the batch axes when
    divisible. Cache k/v leaves ``(n_slots, count, 1, s_max, K, hd)``
    additionally keep the kv-head tensor parallelism of
    ``cache_partition_specs`` (the "model" axis on K when divisible);
    the token cursors, output rows, counters, and the PRNG key are tiny
    and follow the slot axis or stay replicated."""
    sizes = _mesh_sizes(mesh)
    tp = sizes.get("model", 1)
    nb = _nbatch(mesh)
    ba = batch_axes(mesh)

    def one(path, leaf):
        p = normalize_path(jax.tree_util.keystr(path))
        shape = leaf.shape
        nd = len(shape)
        if nd == 0 or p.endswith("/key"):
            return P()
        slot = ba if shape[0] % nb == 0 else None
        if p.endswith("/k") or p.endswith("/v"):
            K = shape[-2]
            k_tp = "model" if (K % tp == 0 and K >= tp) else None
            return P(slot, *((None,) * (nd - 3)), k_tp, None)
        return P(*((slot,) + (None,) * (nd - 1)))

    return jax.tree_util.tree_map_with_path(one, dstate)


def serve_state_shardings(dstate: PyTree, mesh: Mesh) -> PyTree:
    """NamedShardings for the slot-stacked decode state."""
    return shardings_of(serve_state_specs(dstate, mesh), mesh)
