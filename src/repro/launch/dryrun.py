import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, with ZERO device allocation (ShapeDtypeStruct
inputs only):
  * the sharding config is coherent (GSPMD partitions the whole step),
  * memory fits (memory_analysis peak bytes/device vs the 16 GB v5e budget),
  * and extracts cost_analysis FLOPs/bytes + the collective op inventory
    (operand bytes parsed from the HLO text) for §Roofline.

NOTE (§Roofline methodology): cost_analysis counts lax.scan bodies ONCE
(probed empirically), so the per-cell JSON records both the raw compile
numbers and the scan trip counts; benchmarks/roofline.py scales per-layer
unit lowerings by trip count for the roofline table.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch ID] [--shape NAME]
      [--mesh single|multi|both] [--out results/dryrun] [--skip-existing]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, shape_by_name, STANDARD_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch import inputs as inputs_mod
from repro.distributed.sharding import mesh_context, partition_specs
from repro.models.transformer import LanguageModel
from repro.train.state import TrainState
from repro.train.step import make_train_step, resolve_grad_accum
from jax.sharding import NamedSharding, PartitionSpec as P

HBM_BYTES = 16 * 1024**3       # v5e per-chip budget

# Collective parsing lives in the shared static-audit layer since ISSUE 6
# (repro.audit.hlo — one regex, one dtype map for the dry-run inventory,
# the dist_worker audits and the collective-budget pass alike); re-exported
# here for the roofline/multipod benchmarks.
from repro.audit.hlo import parse_collectives  # noqa: E402,F401


def scan_trip_counts(model: LanguageModel):
    return {f"seg{i}": seg.count for i, seg in enumerate(model.plan)}


def build_step(acfg, shape, mesh, scan_layers: bool = True):
    """Returns (fn, example_args, in_shardings, model, donate, info) for
    one cell; ``info`` is a dict of cell metadata (the train cell's
    packed-arena bucket count, DESIGN.md §7, plus the dmd.scope and the
    number of coefficient solves one jump costs under it, DESIGN.md §9 —
    None for serving cells)."""
    info = {"arena_buckets": None, "dmd_scope": None, "jump_solves": None}
    mc = acfg.model
    model = LanguageModel(mc, chunk_k=min(1024, shape.seq_len),
                          remat=acfg.parallel.remat, scan_layers=scan_layers,
                          pad_heads_to=acfg.parallel.pad_attn_heads_to)
    batch, batch_specs = inputs_mod.input_specs(acfg, shape, mesh)

    if shape.kind == "train":
        params = model.init(abstract=True)
        from repro.optim import make_optimizer
        opt = make_optimizer(acfg.optimizer)
        opt_state = jax.eval_shape(opt.init, params)
        from repro.core.accelerator import DMDAccelerator
        acc = DMDAccelerator(acfg.dmd, mesh=mesh,
                             stack_dims=model.param_stack_dims())
        bufs = acc.init(params)    # abstract-aware: ShapeDtypeStruct leaves
        grams = acc.init_grams(bufs)
        # controller state (None unless dmd.controller.enabled): tiny
        # (n_groups,) leaves, abstract like everything else here
        ctrl = acc.init_controller(abstract=True)
        state = TrainState(params, opt_state,
                           jax.ShapeDtypeStruct((), jnp.int32), bufs, grams,
                           ctrl)
        # arena=: bucket-table specs for the packed block-major ring buffers
        # (abstract like everything else here — DESIGN.md §7)
        st_specs = inputs_mod.state_specs(state, mesh,
                                          plans=acc.plans_for(params),
                                          arena=acc.arena_for(params))
        step = make_train_step(model, acfg, mesh=mesh,
                               global_batch=shape.global_batch, acc=acc)
        table = acc.arena_for(params)
        info["arena_buckets"] = len(table)
        info["dmd_scope"] = acc.scope
        # bucket scope: one dmd_coefficients system per bucket, not per
        # leaf — this is the batched-solve row count a full jump traces
        info["jump_solves"] = sum(
            b.gram_lead(acc.scope) for b in table.values())
        # third arg = the step index (the per-group DMD slot vector is
        # derived from it in-trace — train/step.py)
        args = (state, batch, jax.ShapeDtypeStruct((), jnp.int32))
        shardings = (inputs_mod.shardings_of(st_specs, mesh),
                     inputs_mod.shardings_of(batch_specs, mesh),
                     NamedSharding(mesh, P()))
        return step, args, shardings, model, (0,), info  # donate TrainState

    # serving cells
    params = model.init(abstract=True)
    p_specs = partition_specs(params, mesh)
    if shape.kind == "prefill":
        caches = model.init_cache(shape.global_batch, shape.seq_len,
                                  abstract=True)
        c_specs = inputs_mod.cache_partition_specs(caches, mesh)

        def prefill_step(params, batch, caches):
            return model.prefill(params, batch, caches)

        args = (params, batch, caches)
        shardings = (inputs_mod.shardings_of(p_specs, mesh),
                     inputs_mod.shardings_of(batch_specs, mesh),
                     inputs_mod.shardings_of(c_specs, mesh))
        return prefill_step, args, shardings, model, (2,), info  # donate caches

    # decode: one new token against a cache of seq_len
    caches = model.init_cache(shape.global_batch, shape.seq_len,
                              abstract=True)
    c_specs = inputs_mod.cache_partition_specs(caches, mesh)

    def serve_step(params, batch, caches):
        logits, new_caches = model.decode_step(params, batch, caches)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    args = (params, batch, caches)
    shardings = (inputs_mod.shardings_of(p_specs, mesh),
                 inputs_mod.shardings_of(batch_specs, mesh),
                 inputs_mod.shardings_of(c_specs, mesh))
    return serve_step, args, shardings, model, (2,), info   # donate caches


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             skip_existing: bool = False) -> dict:
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    if skip_existing and out_path.exists():
        rec = json.loads(out_path.read_text())
        if rec.get("status") == "ok":
            print(f"[skip] {arch} {shape_name} {mesh_kind}")
            return rec

    acfg = get_config(arch)
    shape = shape_by_name(shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind}
    if shape_name not in acfg.shapes:
        rec["status"] = "skipped"
        rec["note"] = acfg.skip_notes
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[SKIP by design] {arch} {shape_name}: {acfg.skip_notes}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with mesh_context(mesh):
            fn, args, shardings, model, donate, info = build_step(
                acfg, shape, mesh)
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            mstat = lambda name: int(getattr(ma, name, 0) or 0)
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):    # older jaxlibs: one dict
                ca = ca[0] if ca else {}         # per executable
            hlo = compiled.as_text()
            coll, coll_counts = parse_collectives(hlo)

            n_dev = mesh.devices.size
            rec.update({
                "status": "ok",
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "n_devices": n_dev,
                # getattr-guarded: CPU jaxlibs lack some CompiledMemoryStats
                # fields (peak_memory_in_bytes is TPU-only on 0.4.x)
                "memory": {
                    "argument_bytes": mstat("argument_size_in_bytes"),
                    "output_bytes": mstat("output_size_in_bytes"),
                    "temp_bytes": mstat("temp_size_in_bytes"),
                    "peak_bytes": mstat("peak_memory_in_bytes"),
                    "alias_bytes": mstat("alias_size_in_bytes"),
                },
                "fits_hbm": bool(
                    (mstat("argument_size_in_bytes")
                     - mstat("alias_size_in_bytes"))
                    + mstat("peak_memory_in_bytes") < HBM_BYTES * 1.0),
                "cost": {"flops": ca.get("flops"),
                         "bytes_accessed": ca.get("bytes accessed")},
                "collective_bytes_local": coll,
                "collective_counts": coll_counts,
                "scan_trip_counts": scan_trip_counts(model),
                "grad_accum": resolve_grad_accum(acfg, mesh,
                                                 shape.global_batch)
                if shape.kind == "train" else None,
                # packed-arena audit (DESIGN.md §7): how many bucket
                # launches the DMD data passes cost per recorded step
                "dmd_arena_buckets": info["arena_buckets"],
                # bucket-scope audit (DESIGN.md §9): which Koopman scope
                # the cell trains under and how many coefficient solves
                # (batched eig callback rows) one full jump costs
                "dmd_scope": info["dmd_scope"],
                "dmd_jump_solves": info["jump_solves"],
            })
            print(f"[ok] {arch} {shape_name} {mesh_kind}: "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
                  f"args/dev {mstat('argument_size_in_bytes')/2**30:.2f}GiB "
                  f"peak/dev {mstat('peak_memory_in_bytes')/2**30:.2f}GiB "
                  f"colls {sum(coll_counts.values())}")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
        print(f"[FAIL] {arch} {shape_name} {mesh_kind}: "
              f"{type(e).__name__}: {str(e)[:400]}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else [s.name for s in STANDARD_SHAPES]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape_name, mesh_kind, out_dir,
                               args.skip_existing)
                st = rec["status"]
                n_ok += st == "ok"
                n_fail += st == "error"
                n_skip += st == "skipped"
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} fail={n_fail} "
          f"skipped-by-design={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
