"""Serving launcher: a thin CLI over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced [--requests 12] [--new-tokens 16] [--sampling topk] \
        [--swap-every 8]

Submits a mixed-length synthetic request stream to ``repro.serve``'s
``ServeEngine`` (DESIGN.md §10): padded prompt/batch buckets — one
compiled program per bucket, zero steady-state recompiles — slot-based
decode over donated KV/decode state with in-jit sampling (no host sync
per token), and optional live weight hot-swaps mid-stream
(``--swap-every``) to demo the version-stamped double-buffered publish
path. On TPU slices the full config runs on the production mesh with the
slot table sharded per ``launch/inputs.serve_state_specs``.

The per-token decode loop of the seed-era launcher (an
``argmax(logits[:, -1])`` host round-trip between every pair of
dispatches) lives on only inside the engine's jitted decode program;
``serve_fns`` below stays as the audited two-program serving contract
the engine's decode donation mirrors (tests/test_serve_audit.py).
"""
import argparse
import time


def serve_fns(model, donate=True):
    """The serving programs, jitted the way the engine runs them: the KV
    caches (positional arg 2 of both prefill and decode_step) are donated
    so the per-token cache update is in-place — a decode step that COPIES
    its caches doubles the serving HBM footprint and shows up in the
    compiled HLO as cache-shaped copy ops. tests/test_serve_audit.py
    routes both programs through the shared donation/collective passes
    (``python -m repro.audit`` machinery, DESIGN.md §8); ``donate=False``
    exists only so that audit can prove it bites."""
    import jax
    dn = (2,) if donate else ()
    return {"prefill": jax.jit(model.prefill, donate_argnums=dn),
            "decode_step": jax.jit(model.decode_step, donate_argnums=dn)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--sampling", choices=("greedy", "topk"),
                    default="greedy")
    ap.add_argument("--swap-every", type=int, default=0,
                    help="hot-swap perturbed weights every N engine steps "
                         "(0 = frozen server)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.distributed.sharding import mesh_context
    from repro.models.transformer import LanguageModel
    from repro.serve import ServeConfig, ServeEngine

    acfg = get_config(args.arch)
    mc = reduced(acfg.model) if args.reduced else acfg.model
    mesh_cm = None
    if not args.reduced:
        from repro.launch.mesh import make_production_mesh
        mesh_cm = mesh_context(make_production_mesh(
            multi_pod=args.multi_pod))

    def run():
        # scan_layers=False: serving unrolls the layer stack so XLA updates
        # the donated caches fully in place — a lax.scan over layers carries
        # the stacked cache as (xs, stacked-ys) and double-buffers it by
        # construction, which both costs a cache-sized copy per token and
        # would trip the serve donation audit (tests/test_serve_audit.py).
        model = LanguageModel(mc, head_tp=not args.reduced, chunk_k=64,
                              scan_layers=False)
        params = model.init(jax.random.PRNGKey(0))
        cfg = ServeConfig(n_slots=args.slots, prompt_buckets=(16, 64),
                          batch_buckets=(1, 4), sampling=args.sampling,
                          max_new_tokens=args.new_tokens,
                          adopt="step")
        engine = ServeEngine(model, params, cfg)
        rng = np.random.default_rng(0)
        for _ in range(args.requests):
            n = int(rng.integers(4, cfg.prompt_buckets[-1] + 1))
            engine.submit(rng.integers(
                1, mc.vocab_size, size=(n,)).tolist())

        swap_src = jax.tree_util.tree_map(lambda l: l * 1.001, params)
        done, steps = [], 0
        t0 = time.time()
        while engine.queue_len or engine.active_slots:
            done.extend(engine.step())
            steps += 1
            if args.swap_every and steps % args.swap_every == 0:
                engine.swap_weights(swap_src)
        engine.sync()
        wall = time.time() - t0
        s = engine.stats
        print(f"{len(done)} requests, {s['tokens_emitted']} tokens in "
              f"{wall*1e3:.0f}ms -> {s['tokens_emitted']/max(wall,1e-9):.0f}"
              f" tok/s | swaps={s['swaps']} dropped={s['dropped']} "
              f"programs={engine.n_programs}/{engine.max_programs}")
        first = min(done, key=lambda r: r.uid)
        print(f"ids[{first.uid}] v{first.version_start}->"
              f"{first.version_end}: {first.tokens}")

    if mesh_cm is not None:
        with mesh_cm:
            run()
    else:
        run()


if __name__ == "__main__":
    main()
