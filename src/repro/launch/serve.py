"""Batched serving launcher: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --reduced \
        [--batch 4] [--prompt-len 32] [--new-tokens 16] [--multi-pod]

On TPU slices this serves the full config on the production mesh (KV caches
sharded per launch/inputs.py rules: kv-head TP when divisible, sequence-
sharded flash-decoding otherwise).
"""
import argparse
import time


def serve_fns(model, donate=True):
    """The serving programs, jitted the way ``main`` runs them: the KV
    caches (positional arg 2 of both prefill and decode_step) are donated
    so the per-token cache update is in-place — a decode step that COPIES
    its caches doubles the serving HBM footprint and shows up in the
    compiled HLO as cache-shaped copy ops. tests/test_serve_audit.py
    routes both programs through the shared donation/collective passes
    (``python -m repro.audit`` machinery, DESIGN.md §8); ``donate=False``
    exists only so that audit can prove it bites."""
    import jax
    dn = (2,) if donate else ()
    return {"prefill": jax.jit(model.prefill, donate_argnums=dn),
            "decode_step": jax.jit(model.decode_step, donate_argnums=dn)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.distributed.sharding import mesh_context
    from repro.models.transformer import LanguageModel

    acfg = get_config(args.arch)
    mc = reduced(acfg.model) if args.reduced else acfg.model
    mesh_cm = None
    if not args.reduced:
        from repro.launch.mesh import make_production_mesh
        mesh_cm = mesh_context(make_production_mesh(
            multi_pod=args.multi_pod))

    def run():
        # scan_layers=False: serving unrolls the layer stack so XLA updates
        # the donated caches fully in place — a lax.scan over layers carries
        # the stacked cache as (xs, stacked-ys) and double-buffers it by
        # construction, which both costs a cache-sized copy per token and
        # would trip the serve donation audit (tests/test_serve_audit.py).
        model = LanguageModel(mc, head_tp=not args.reduced, chunk_k=64,
                              scan_layers=False)
        params = model.init(jax.random.PRNGKey(0))
        B, P, N = args.batch, args.prompt_len, args.new_tokens
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (B, P), 0, mc.vocab_size)}
        if mc.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(P)[None, None, :], (B, 3, P))
        if mc.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, mc.encoder_seq_len, mc.d_model))
        caches = model.init_cache(B, P + N)
        fns = serve_fns(model)
        prefill, decode = fns["prefill"], fns["decode_step"]
        t0 = time.time()
        logits, caches = prefill(params, batch, caches)
        jax.block_until_ready(logits)
        t_pre = time.time() - t0
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        t0 = time.time()
        out = [tok]
        for i in range(N - 1):
            d = {"tokens": tok}
            if mc.mrope_sections:
                d["positions"] = jnp.full((B, 3, 1), P + i, jnp.int32)
            logits, caches = decode(params, d, caches)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(tok)
        jax.block_until_ready(out[-1])
        t_dec = time.time() - t0
        print(f"prefill({P})={t_pre*1e3:.0f}ms decode({N-1})="
              f"{t_dec*1e3:.0f}ms -> {(N-1)*B/max(t_dec,1e-9):.0f} tok/s")
        print("ids[0]:", jnp.concatenate(out, 1)[0].tolist())

    if mesh_cm is not None:
        with mesh_cm:
            run()
    else:
        run()


if __name__ == "__main__":
    main()
