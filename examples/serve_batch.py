"""Batched serving demo: prefill a batch of prompts, then decode greedily.

    PYTHONPATH=src python examples/serve_batch.py [--arch gemma3-27b]
        [--batch 4] [--prompt-len 32] [--new-tokens 16]

Exercises the production serving path (prefill -> KV caches incl. ring
caches for sliding-window layers -> decode steps) on a reduced config.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models.transformer import LanguageModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    acfg = get_config(args.arch)
    mc = reduced(acfg.model)
    model = LanguageModel(mc, head_tp=False, chunk_k=64)
    params = model.init(jax.random.PRNGKey(0))
    B, P, N = args.batch, args.prompt_len, args.new_tokens

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 mc.vocab_size)
    batch = {"tokens": prompts}
    if mc.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(P)[None, None, :], (B, 3, P))
    if mc.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, mc.encoder_seq_len, mc.d_model))

    caches = model.init_cache(B, P + N)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]

    generated = [next_tok]
    t0 = time.time()
    for i in range(N - 1):
        dbatch = {"tokens": next_tok}
        if mc.mrope_sections:
            dbatch["positions"] = jnp.full((B, 3, 1), P + i, jnp.int32)
        logits, caches = decode(params, dbatch, caches)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        generated.append(next_tok)
    jax.block_until_ready(generated[-1])
    t_decode = time.time() - t0

    tokens = jnp.concatenate(generated, axis=1)
    print(f"arch={args.arch} (reduced) B={B}")
    print(f"prefill {P} tokens: {t_prefill*1e3:.0f} ms "
          f"(incl. compile)")
    print(f"decode {N-1} steps: {t_decode*1e3:.0f} ms "
          f"-> {(N-1)*B/max(t_decode,1e-9):.0f} tok/s (batch)")
    print("generated ids[0]:", tokens[0].tolist())


if __name__ == "__main__":
    main()
