"""Continuous-batching serving demo: mixed-length request stream with a
live weight hot-swap mid-flight (DESIGN.md §10).

    PYTHONPATH=src python examples/serve_batch.py [--arch tinyllama-1.1b]
        [--requests 10] [--new-tokens 8] [--swap]

Drives ``repro.serve.ServeEngine``: prompts are packed into padded
prompt/batch buckets (one compiled program per bucket — the demo prints
the program registry to show steady state never recompiles), decode runs
over donated slot-stacked KV caches with in-jit greedy sampling (zero
host syncs per token), and ``--swap`` publishes perturbed weights
through a ``WeightsChannel`` (the same atomic checkpoint machinery the
trainer's publish hook uses) while requests are in flight — the report
shows which weight version each request started and finished on.

Needs only the pyproject pythonpath (``PYTHONPATH=src`` or an editable
install) — no sys.path hacks.
"""
import argparse
import tempfile
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    help="any dense/moe KV-cache arch (ring-cache and SSM "
                         "families are not servable by the engine yet)")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--swap", action="store_true",
                    help="hot-swap perturbed weights mid-stream via a "
                         "WeightsChannel publish")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models.transformer import LanguageModel
    from repro.serve import ServeConfig, ServeEngine, WeightsChannel

    acfg = get_config(args.arch)
    mc = reduced(acfg.model, n_layers=2, d_model=64, d_ff=128,
                 vocab_size=256, n_heads=2, n_kv_heads=2, head_dim=32)
    # scan_layers=False is the serving build (launch/serve.py): unrolled
    # layers keep the donated slot-stacked cache update fully in place.
    model = LanguageModel(mc, head_tp=False, chunk_k=16, scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))

    cfg = ServeConfig(n_slots=4, prompt_buckets=(8, 16), batch_buckets=(1, 2),
                      max_new_tokens=args.new_tokens)
    engine = ServeEngine(model, params, cfg)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        n = int(rng.integers(2, cfg.prompt_buckets[-1] + 1))
        engine.submit(rng.integers(1, mc.vocab_size, size=(n,)).tolist())

    done = []
    t0 = time.time()
    if args.swap:
        with tempfile.TemporaryDirectory() as root:
            channel = WeightsChannel(root)
            bumped = jax.tree_util.tree_map(lambda l: l * 1.001, params)
            swapped = False
            while engine.queue_len or engine.active_slots:
                done.extend(engine.step())
                if not swapped and engine.stats["completed"] >= 2:
                    # trainer side: publish; server side: poll + swap
                    channel.publish(bumped, version=100)
                    channel.poll(engine, params)
                    swapped = True
    else:
        done = engine.run_until_drained()
    engine.sync()
    wall = time.time() - t0

    s = engine.stats
    print(f"arch={args.arch} (reduced) slots={cfg.n_slots}")
    print(f"{len(done)} requests, {s['tokens_emitted']} tokens in "
          f"{wall*1e3:.0f} ms -> "
          f"{s['tokens_emitted']/max(wall,1e-9):.0f} tok/s "
          f"(incl. {s['compiles']} compiles)")
    print(f"programs={engine.n_programs}/{engine.max_programs} "
          f"steady_compiles={s['steady_compiles']} swaps={s['swaps']} "
          f"dropped={s['dropped']}")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req{r.uid} prompt={r.prompt_len} "
              f"v{r.version_start}->v{r.version_end}: {r.tokens}")


if __name__ == "__main__":
    main()
