"""End-to-end LM training driver with the full production stack:
Trainer loop + DMD acceleration + checkpoint/resume + deterministic data.

    PYTHONPATH=src python examples/lm_train.py [--steps 150] [--dmd]
        [--ckpt /tmp/lm_ckpt] [--arch tinyllama-1.1b] [--width 256]

Uses a depth/width-reduced variant of the chosen arch (same family/topology)
sized for CPU; on TPU drop --width to run the true config via configs/.
Kill it mid-run and rerun with the same --ckpt: it resumes bit-exactly.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import DMDConfig, OptimizerConfig, TrainConfig
from repro.data.tokens import synthetic_lm_batches
from repro.models.transformer import LanguageModel
from repro.train import Trainer
from repro.checkpoint import latest_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--dmd", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    acfg = get_config(args.arch)
    mc = reduced(acfg.model, n_layers=args.layers, d_model=args.width,
                 d_ff=args.width * 4, vocab_size=2048,
                 n_heads=max(args.width // 64, 1),
                 n_kv_heads=max(args.width // 128, 1), head_dim=64)
    acfg = dataclasses.replace(
        acfg, model=mc,
        dmd=DMDConfig(enabled=args.dmd, m=8, s=24, tol=1e-4,
                      warmup_steps=40, cooldown_steps=6,
                      snapshot_dtype="float32"),
        optimizer=OptimizerConfig(name="adamw", lr=6e-4, weight_decay=0.1,
                                  grad_clip=1.0, schedule="cosine",
                                  warmup_steps=20, total_steps=args.steps),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=args.batch, seq_len=args.seq,
                          checkpoint_every=50, checkpoint_dir=args.ckpt,
                          keep_checkpoints=2))

    model = LanguageModel(mc, head_tp=False, chunk_k=min(args.seq, 512))
    n_params = model.param_count()
    print(f"{args.arch} (reduced): {n_params / 1e6:.1f}M params, "
          f"dmd={'on' if args.dmd else 'off'}")

    trainer = Trainer(model, acfg, checkpoint_dir=args.ckpt or None)
    start = (latest_step(args.ckpt) or 0) if args.ckpt else 0
    if start:
        print(f"resuming from checkpoint at step {start}")
    batches = synthetic_lm_batches(0, args.batch, args.seq, mc.vocab_size,
                                   start_step=start)
    t0 = time.time()
    trainer.fit(batches, steps=args.steps, log_every=10)
    dt = time.time() - t0
    tok_s = (args.steps - start) * args.batch * args.seq / max(dt, 1e-9)
    print(f"done: {dt:.1f}s, {tok_s:,.0f} tokens/s")


if __name__ == "__main__":
    main()
