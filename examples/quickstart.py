"""Quickstart: DMD-accelerated training of a tiny LM on synthetic tokens.

    PYTHONPATH=src python examples/quickstart.py [--steps N]

Trains the same model twice (plain Adam vs Adam + DMD extrapolation at equal
optimizer-step budget) and prints both loss curves. `--steps` shrinks the
run (the CI examples smoke lane uses a short budget).
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import DMDConfig, OptimizerConfig, TrainConfig
from repro.data.tokens import synthetic_lm_batches
from repro.models.transformer import LanguageModel
from repro.train import Trainer


def build(dmd_enabled: bool):
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=4, d_model=128, d_ff=256,
                 vocab_size=512, n_heads=4, n_kv_heads=2, head_dim=32)
    acfg = dataclasses.replace(
        acfg, model=mc,
        dmd=DMDConfig(enabled=dmd_enabled, m=8, s=24, tol=1e-4,
                      warmup_steps=40, cooldown_steps=6),
        optimizer=OptimizerConfig(name="adam", lr=1e-3, schedule="constant"),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=8, seq_len=64))
    model = LanguageModel(mc, head_tp=False, chunk_k=64)
    return Trainer(model, acfg), mc


def run(dmd_enabled: bool, steps: int = 200):
    trainer, mc = build(dmd_enabled)
    batches = synthetic_lm_batches(0, 8, 64, mc.vocab_size)
    losses = []
    t0 = time.time()
    trainer.fit(batches, steps=steps,
                on_metrics=lambda s, m: losses.append(float(m["loss"])))
    return losses, time.time() - t0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    base, t_base = run(False, steps=args.steps)
    dmd, t_dmd = run(True, steps=args.steps)
    print(f"\n{'step':>6} {'baseline':>10} {'dmd':>10}")
    for s in range(0, len(base), 25):
        print(f"{s:>6} {base[s]:>10.4f} {dmd[s]:>10.4f}")
    print(f"final  {base[-1]:>10.4f} {dmd[-1]:>10.4f}")
    print(f"\nwall: baseline {t_base:.1f}s, dmd {t_dmd:.1f}s "
          f"(overhead {t_dmd / t_base:.2f}x; paper's TF impl saw 1.41x, "
          f"in-graph JAX stays near the 1.07x theoretical)")
