"""The paper's experiment end-to-end: pollutant-dispersion surrogate.

    PYTHONPATH=src python examples/pollutant_regression.py \
        [--samples 300] [--epochs 1200] [--full]

1. Generates the dataset by solving the Blasius + advection-diffusion-
   reaction system per LHS parameter sample (Appendix 1).
2. Trains the paper's softsign MLP (6-40-200-1000-2670) with Adam, with and
   without DMD acceleration (m=14, s=55 — the paper's selected values).
3. Reports train/test MSE for both and the per-jump relative improvements.

--full uses the paper's exact scale (1000 samples, 3000 epochs, tol=1e-10,
float64) — several hours on this CPU; the default reduced run reproduces the
qualitative claims in ~15 minutes.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import DMDConfig, OptimizerConfig
from repro.core import DMDAccelerator
from repro.data import pollutant as pol
from repro.models.mlp_net import init_mlp, mse_loss
from repro.optim import apply_updates, make_optimizer


def train(Xtr, Ytr, Xte, Yte, sizes, dmd_cfg, epochs, lr=1e-3, seed=0,
          log_every=200, guard=True):
    params = init_mlp(jax.random.PRNGKey(seed), sizes)
    opt = make_optimizer(OptimizerConfig(name="adam", lr=lr))
    state = opt.init(params)
    acc = DMDAccelerator(dmd_cfg)
    bufs = acc.init(params)

    @jax.jit
    def step(p, s, t):
        loss, g = jax.value_and_grad(lambda pp: mse_loss(pp, Xtr, Ytr))(p)
        u, s = opt.update(g, s, p, t)
        return apply_updates(p, u), s, loss

    jumps = []
    tr_curve, te_curve = [], []
    for t in range(epochs):
        params, state, loss = step(params, state, jnp.asarray(t))
        if dmd_cfg.enabled and acc.should_record(t):
            # acc.slots(t) = per-group slot vector: groups mid-cooldown or
            # phase-delayed are skipped; with no group rules this is the
            # paper's single global window.
            bufs, _ = acc.record(bufs, params, acc.slots(t))
        if dmd_cfg.enabled and acc.should_apply(t):
            before = float(mse_loss(params, Xtr, Ytr))
            old_params = jax.tree_util.tree_map(
                lambda x: x.copy(), params)
            # jump only the group(s) whose window closed at t (staggered
            # configs: at most one group's spike per step)
            params, _ = acc.apply(params, bufs, step=t)
            after = float(mse_loss(params, Xtr, Ytr))
            jumps.append(after / max(before, 1e-30))
            if guard and after > before:
                # validated jump: revert harmful extrapolations (the
                # loss check costs one forward; the paper's "annealing
                # needed" note, made concrete)
                params = old_params
            else:
                # group-masked moment reset: only the jumped groups whose
                # schedule keeps reset_opt on restart their Adam moments
                from repro.train.step import reset_opt_state_after_jump
                reset = acc.reset_groups(acc.apply_groups(t))
                if reset:
                    state = reset_opt_state_after_jump(
                        opt, state, params, acc.plans_for(params), reset,
                        acc.n_groups)
        if t % log_every == 0 or t == epochs - 1:
            tr = float(mse_loss(params, Xtr, Ytr))
            te = float(mse_loss(params, Xte, Yte))
            tr_curve.append((t, tr))
            te_curve.append((t, te))
            print(f"  epoch {t:5d}: train {tr:.5e}  test {te:.5e}")
    return params, tr_curve, te_curve, jumps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=300)
    ap.add_argument("--epochs", type=int, default=1200)
    ap.add_argument("--points", type=int, default=2670)
    ap.add_argument("--grid", type=int, nargs=2, default=(64, 32))
    ap.add_argument("--full", action="store_true",
                    help="paper-exact: 1000 samples, 3000 epochs, fp64")
    ap.add_argument("--staggered", action="store_true",
                    help="per-leaf schedule: matrices m=14/phase 0, "
                         "biases m=6/phase 7 (staggered asynchronous jumps)")
    args = ap.parse_args()
    if args.full:
        args.samples, args.epochs, args.grid = 1000, 3000, (96, 48)
        jax.config.update("jax_enable_x64", True)

    print(f"generating dataset: {args.samples} PDE solves on "
          f"{args.grid[0]}x{args.grid[1]} grid ...")
    t0 = time.time()
    data = pol.generate_dataset(n_samples=args.samples, nx=args.grid[0],
                                ny=args.grid[1], n_points=args.points,
                                seed=0, batch=32, verbose=True)
    (Xtr, Ytr), (Xte, Yte) = pol.train_test_split(data, 0.8)
    print(f"dataset ready in {time.time() - t0:.0f}s: "
          f"train {Xtr.shape} -> {Ytr.shape}, test {Xte.shape}")
    Xtr, Ytr = jnp.asarray(Xtr), jnp.asarray(Ytr)
    Xte, Yte = jnp.asarray(Xte), jnp.asarray(Yte)

    sizes = (6, 40, 200, 1000, args.points)

    if args.full:
        # Paper-faithful DMD: plain (unanchored) formulation, eig mode,
        # tol=1e-10, no guards — valid in fp64.
        dmd_cfg = DMDConfig(m=14, s=55, tol=1e-10, warmup_steps=28,
                            cooldown_steps=0, anchor="none", affine=False,
                            trust_region=0.0, mode="eig",
                            reset_opt_state=False)
    else:
        dmd_cfg = DMDConfig(m=14, s=55, tol=1e-4, warmup_steps=100,
                            cooldown_steps=10)
    if args.staggered:
        # The two-group schedule from DESIGN.md §4: matrices keep the
        # paper's m=14 window (jump residue odd); biases get short m=6
        # windows phase-shifted by 7 (jump residue even) with a cooldown
        # matching the cycles, a proportional horizon, and no moment reset
        # — the two groups never jump on the same step.
        from repro.core.schedule import DMDGroupRule
        dmd_cfg = dataclasses.replace(
            dmd_cfg, cooldown_steps=0,
            groups=(DMDGroupRule(name="biases", max_ndim=1, m=6, phase=7,
                                 cooldown_steps=8, s=24, reset_opt=False),))

    print("\n=== baseline (plain Adam) ===")
    _, tr_b, te_b, _ = train(Xtr, Ytr, Xte, Yte, sizes,
                             DMDConfig(enabled=False), args.epochs)
    label = "staggered two-group" if args.staggered else "m=14, s=55"
    print(f"\n=== DMD-accelerated ({label}) ===")
    _, tr_d, te_d, jumps = train(Xtr, Ytr, Xte, Yte, sizes, dmd_cfg,
                                 args.epochs)

    print("\n=== summary (paper Fig. 4 analogue) ===")
    print(f"final train MSE: baseline {tr_b[-1][1]:.5e}  "
          f"dmd {tr_d[-1][1]:.5e}  ratio {tr_b[-1][1] / tr_d[-1][1]:.1f}x")
    print(f"final test  MSE: baseline {te_b[-1][1]:.5e}  "
          f"dmd {te_d[-1][1]:.5e}  ratio {te_b[-1][1] / te_d[-1][1]:.1f}x")
    if jumps:
        acc_n = sum(1 for j in jumps if j < 1.0)
        print(f"mean relative improvement per DMD application: "
              f"{np.mean(jumps):.3f} (median {np.median(jumps):.3f}) over "
              f"{len(jumps)} jumps; accepted {acc_n} (paper Fig. 3 metric)")


if __name__ == "__main__":
    main()
